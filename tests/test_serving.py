"""Query-serving front end: seeded open-loop load generation, admission
control, scan-sharing micro-batches (byte-equal to serial execution), and
the unified executor-config surface."""
from __future__ import annotations

import argparse
import random

import jax
import numpy as np
import pytest

from repro.core import config as config_mod
from repro.core.metrics import Samples, compute_metrics
from repro.engine import datagen, queries
from repro.runtime.loadgen import arrival_times, generate_trace, sample_params
from repro.runtime.requests import QueryRequest, RequestQueue
from repro.runtime.serve_query import (
    QueryServer,
    measure_saturation,
    run_open_loop,
)

ROWS = 2_000


@pytest.fixture(scope="module")
def plans():
    li = datagen.lineitem(jax.random.PRNGKey(0), rows=ROWS)
    od = datagen.orders(jax.random.PRNGKey(1), rows=ROWS // 4)
    return queries.make_serving_plans(li, od)


# -- open-loop load generation -------------------------------------------------
def test_poisson_arrivals_reproducible():
    a = arrival_times(200.0, 1.0, arrival="poisson", seed=7)
    b = arrival_times(200.0, 1.0, arrival="poisson", seed=7)
    assert a == b
    assert a != arrival_times(200.0, 1.0, arrival="poisson", seed=8)
    assert all(0.0 <= t < 1.0 for t in a)
    assert a == sorted(a)
    # Poisson(200/s) over 1s: far from degenerate on either side.
    assert 100 < len(a) < 400


def test_fixed_arrivals_exact():
    assert arrival_times(10.0, 1.0, arrival="fixed") == [i / 10.0 for i in range(10)]


def test_trace_deterministic_and_round_robin():
    t1 = generate_trace(["q1", "q6"], 100.0, 0.5, arrival="poisson", seed=3)
    t2 = generate_trace(["q1", "q6"], 100.0, 0.5, arrival="poisson", seed=3)
    assert [(r.uid, r.query, r.params, r.arrival_s) for r in t1] == [
        (r.uid, r.query, r.params, r.arrival_s) for r in t2
    ]
    assert [r.query for r in t1[:4]] == ["q1", "q6", "q1", "q6"]
    # a different seed moves both arrivals and constants
    t3 = generate_trace(["q1", "q6"], 100.0, 0.5, arrival="poisson", seed=4)
    assert [r.params for r in t1] != [r.params for r in t3]


def test_sample_params_in_kernel_domain():
    rng = random.Random(0)
    for _ in range(50):
        p = sample_params("q6", rng)
        assert 1993 <= p["year"] <= 1997
        assert 0.02 <= p["discount"] <= 0.09
    with pytest.raises(ValueError):
        sample_params("q99", rng)


# -- admission control ---------------------------------------------------------
def test_request_queue_sheds_exactly_overflow():
    q = RequestQueue(depth=4)
    admitted = [q.submit(i) for i in range(7)]
    assert admitted == [True] * 4 + [False] * 3
    assert (q.offered, q.admitted, q.shed) == (7, 4, 3)
    assert [q.popleft() for _ in range(len(q))] == [0, 1, 2, 3]  # FIFO
    # draining frees capacity again
    assert q.submit(99) is True
    assert (q.offered, q.admitted, q.shed) == (8, 5, 3)


def test_request_queue_take_matching_preserves_order():
    q = RequestQueue()
    for i, name in enumerate(["a", "b", "a", "a", "b", "a"]):
        q.submit((i, name))
    taken = q.take_matching(lambda r: r[1] == "a", limit=3)
    assert [i for i, _ in taken] == [0, 2, 3]
    assert list(q) == [(1, "b"), (4, "b"), (5, "a")]  # untouched order


def test_request_queue_thread_safe_under_hammer():
    """Regression: submit/take_matching raced before the internal lock.

    8 submitter threads push disjoint uid ranges while 4 drainers spin
    take_matching; afterwards every admitted request must have been taken
    exactly once and the counters must satisfy offered == admitted + shed.
    """
    import threading

    q = RequestQueue(depth=64)
    n_submitters, per_thread = 8, 500
    taken: list = []
    taken_lock = threading.Lock()
    done = threading.Event()

    def submitter(base):
        for i in range(per_thread):
            q.submit((base + i, "a" if i % 2 else "b"))

    def drainer():
        while not done.is_set() or len(q):
            got = q.take_matching(lambda r: True, limit=7)
            if got:
                with taken_lock:
                    taken.extend(got)

    drainers = [threading.Thread(target=drainer) for _ in range(4)]
    for t in drainers:
        t.start()
    submitters = [
        threading.Thread(target=submitter, args=(k * per_thread,))
        for k in range(n_submitters)
    ]
    for t in submitters:
        t.start()
    for t in submitters:
        t.join()
    done.set()
    for t in drainers:
        t.join()

    assert q.offered == n_submitters * per_thread
    assert q.offered == q.admitted + q.shed  # the invariant the lock protects
    assert len(taken) == q.admitted  # nothing lost, nothing duplicated
    assert len({uid for uid, _ in taken}) == len(taken)


def test_server_sheds_at_oversaturation(plans):
    server = QueryServer(plans, queue_depth=2, max_batch=4)
    reqs = [
        QueryRequest(uid=i, query="q6", params=sample_params("q6", random.Random(i)))
        for i in range(6)
    ]
    results = [server.submit(r) for r in reqs]
    assert results == [True, True, False, False, False, False]
    assert server.queue.shed == 4
    done = server.step()
    assert {c.uid for c in done} == {0, 1}
    assert done[0].batch_size == 2


# -- scan sharing: byte-identical to serial ------------------------------------
@pytest.mark.parametrize("qname", ["q1", "q6", "q12"])
@pytest.mark.parametrize("use_pallas", [True, False])
def test_micro_batch_byte_equals_serial(plans, qname, use_pallas):
    rng = random.Random(11)
    param_list = [sample_params(qname, rng) for _ in range(5)]
    batched = queries.fused_query_batch(plans[qname], param_list, use_pallas=use_pallas)
    for params, got in zip(param_list, batched):
        want = queries.fused_query_serial(plans[qname], params, use_pallas=use_pallas)
        assert set(want) == set(got)
        for k in want:
            assert np.array_equal(np.asarray(want[k]), np.asarray(got[k])), (qname, k)


def test_server_batched_results_byte_equal_serial(plans):
    """End to end through the scheduler tick: coalesced completions carry
    the exact bytes serial per-request execution would have produced."""
    rng = random.Random(5)
    reqs = [
        QueryRequest(uid=i, query="q6", params=sample_params("q6", rng)) for i in range(7)
    ]
    server = QueryServer(plans, max_batch=8)
    for r in reqs:
        server.submit(r)
    done = server.step()
    assert len(done) == 7 and all(c.batch_size == 7 for c in done)
    for req, c in zip(reqs, done):
        assert c.uid == req.uid
        want = queries.fused_query_serial(plans["q6"], req.params)
        for k in want:
            assert np.array_equal(np.asarray(want[k]), np.asarray(c.result[k]))
    assert server.kernel_calls == 1  # one HBM pass for all seven requests


def test_server_coalesces_only_same_query_shape(plans):
    server = QueryServer(plans, max_batch=8)
    rng = random.Random(0)
    for i, name in enumerate(["q6", "q1", "q6"]):
        server.submit(QueryRequest(uid=i, query=name, params=sample_params(name, rng)))
    first = server.step()
    assert [c.uid for c in first] == [0, 2]  # both q6s, one pass
    second = server.step()
    assert [c.uid for c in second] == [1]
    assert server.kernel_calls == 2


# -- percentile math -----------------------------------------------------------
def test_p50_p99_match_numpy_percentile():
    lat = [0.004, 0.001, 0.010, 0.002, 0.007, 0.003, 0.009, 0.005]
    s = Samples(times_s=list(lat))
    got = compute_metrics(s, ("p50_latency_us", "p99_latency_us"))
    assert got["p50_latency_us"] == pytest.approx(1e6 * float(np.percentile(lat, 50)))
    assert got["p99_latency_us"] == pytest.approx(1e6 * float(np.percentile(lat, 99)))


# -- open-loop serving runs ----------------------------------------------------
def test_open_loop_run_below_saturation_sheds_nothing(plans):
    server = QueryServer(plans, queue_depth=32, max_batch=8)
    server.warmup(["q6"])
    trace = generate_trace(["q6"], 40.0, 0.4, arrival="fixed", seed=0)
    report = run_open_loop(server, trace)
    assert report.offered == len(trace)
    assert report.shed == 0
    assert len(report.completed) == len(trace)
    assert sorted(c.uid for c in report.completed) == [r.uid for r in trace]
    assert all(c.latency_s >= 0 for c in report.completed)
    assert report.qps > 0


def test_measure_saturation_positive(plans):
    qps = measure_saturation(plans, ["q6"], max_batch=4, n_requests=8)
    assert qps > 0


# -- serving task through the framework ----------------------------------------
def test_serving_task_reports_latency_and_saturation():
    from repro.core.registry import get
    from repro.core.task import TaskContext

    task = get("serving")
    ctx = TaskContext(platform={"name": "cpu-host"})
    task.prepare(ctx)
    s = task.run(
        ctx,
        {"scale": "0.001", "query": "q6", "rate": 30.0, "arrival": "fixed",
         "batching": True, "duration": 0.3, "queue_depth": 64, "seed": 0},
    )
    vals = compute_metrics(
        s, ("p50_latency_us", "p99_latency_us", "qps", "saturation_qps", "shed_requests")
    )
    assert vals["p50_latency_us"] > 0
    assert vals["p99_latency_us"] >= vals["p50_latency_us"]
    assert vals["saturation_qps"] > 0
    assert vals["shed_requests"] == 0
    assert len(s.times_s) == int(vals["completed_requests"])
    task.clean(ctx)


def test_serving_task_dilates_rates_on_simulated_platform():
    from repro.core.platform import get_platform
    from repro.core.registry import get
    from repro.core.task import TaskContext

    task = get("serving")
    ctx = TaskContext(platform={"name": "dpu-sim"})
    task.prepare(ctx)
    params = {"scale": "0.001", "query": "q6", "rate": 30.0, "arrival": "fixed",
              "batching": False, "duration": 0.2, "queue_depth": 0, "seed": 0}
    s = task.run(ctx, params)
    ts = get_platform("dpu-sim").time_scale
    assert ts > 1
    # rates were pre-divided: offered load 30/s reads as 30/ts on the sim
    assert s.extra["offered_qps"] == pytest.approx(30.0 / ts, rel=0.25)
    task.clean(ctx)


# -- unified executor-config API -----------------------------------------------
def test_sweep_config_round_trip_and_executor_mapping(tmp_path):
    p = argparse.ArgumentParser()
    config_mod.add_sweep_args(p)
    ns = p.parse_args(
        ["--iters", "7", "--warmup", "3", "--workers", "4", "--pool", "process",
         "--platforms", "cpu-host", "dpu-sim", "--schedule", "static",
         "--straggler-factor", "2.5", "--min-time", "0.1",
         "--cache", str(tmp_path / "c.json"), "--weighted-shard"]
    )
    cfg = config_mod.SweepConfig.from_args(ns)
    assert cfg.iters == 7 and cfg.warmup == 3 and cfg.workers == 4
    assert cfg.platforms == ["cpu-host", "dpu-sim"]
    ex = config_mod.make_executor(cfg)
    assert ex.iters == 7 and ex.warmup == 3 and ex.workers == 4
    assert ex.pool == "process" and ex.schedule == "static"
    assert ex.straggler_factor == 2.5 and ex.min_time_s == pytest.approx(0.1)
    assert ex.weighted_shard is True
    assert [pl.name for pl in ex.platforms] == ["cpu-host", "dpu-sim"]
    assert ex.cache is not None


def test_cache_file_is_alias_of_cache(tmp_path):
    p = argparse.ArgumentParser()
    config_mod.add_sweep_args(p)
    ns = p.parse_args(["--cache-file", str(tmp_path / "c.json")])
    assert ns.cache_path == str(tmp_path / "c.json")
    ns2 = p.parse_args(["--cache", str(tmp_path / "c.json")])
    assert ns2.cache_path == ns.cache_path


def test_no_cache_wins(tmp_path):
    cfg = config_mod.SweepConfig(cache_path=str(tmp_path / "c.json"), no_cache=True)
    assert config_mod.make_cache(cfg) is None
    assert config_mod.make_cache(config_mod.SweepConfig()) is None  # no path at all
    assert config_mod.make_cache(
        config_mod.SweepConfig(), default_path=tmp_path / "d.json"
    ) is not None


def test_cli_surfaces_share_sweep_flags():
    """The three entry points expose identical sweep flag sets (no drift)."""
    import benchmarks.run as bench_run
    from repro.core import runner as runner_mod
    from repro.runtime import serve_query

    def sweep_flags(build_parser):
        p = argparse.ArgumentParser()
        build_parser(p)
        return {
            s for a in p._actions for s in a.option_strings
        }

    base = sweep_flags(config_mod.add_sweep_args)
    assert "--cache" in base and "--cache-file" in base and "--shard" in base
    # Each CLI parses a sweep-only command line identically.
    for main in (runner_mod.main, bench_run.main, serve_query.main):
        with pytest.raises(SystemExit) as e:
            main(["--bogus-flag-that-cannot-exist"])
        assert e.value.code == 2
    # And accepts the shared flags without argparse errors (--list-style
    # early exits keep the parse cheap).
    assert runner_mod.main(["--list-tasks"]) == 0
    assert bench_run.main(["--list", "--workers", "3", "--shard", "0/2"]) == 0


def test_serving_box_runs_through_runner():
    from repro.core.box import Box
    from repro.core.runner import Runner

    box = Box.from_dict(
        {
            "name": "serving_smoke_box",
            "tasks": [
                {
                    "task": "serving",
                    "params": {"scale": "0.001", "query": ["q6"], "rate": 30.0,
                               "arrival": "fixed", "batching": True,
                               "duration": 0.2, "queue_depth": 32, "seed": 0},
                    "metrics": ["p50_latency_us", "p99_latency_us", "qps",
                                "saturation_qps", "shed_requests"],
                }
            ],
        }
    )
    res = Runner(platform="cpu-host", iters=1, warmup=0).run_box(box)
    assert not res.errors
    assert len(res.rows) == 1
    row = res.rows[0]
    assert row["p99_latency_us"] >= row["p50_latency_us"] > 0
    assert row["saturation_qps"] > 0
    assert row["shed_requests"] == 0


def test_serve_cli_smoke(tmp_path, capsys):
    from repro.runtime import serve_query

    out = tmp_path / "serve.csv"
    rc = serve_query.main(
        ["--query", "q6", "--arrival-rate", "30", "--duration", "0.2",
         "--arrival", "fixed", "--platforms", "cpu-host", "--out", str(out)]
    )
    assert rc == 0
    text = out.read_text()
    assert "p50_latency_us" in text and "saturation_qps" in text
