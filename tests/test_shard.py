"""Executor conformance suite for distributed sweeps.

Three pillars, per the sharding/remote subsystem's contract:

  1. *Partition laws* — the consistent-hash shard assignment is a disjoint
     cover of any key set, and resizing n -> n+1 shards keeps at least
     (1 - 2/n) of keys on their shard (property tests via the
     _hypothesis_compat shim, so they run with or without hypothesis).
  2. *Shard conformance* — for every pool kind (sequential, thread,
     process), the merged union of all shard runs is row-identical to the
     unsharded run, and a shared result cache dedupes points across shards.
  3. *Remote transport* — a loopback worker (in-process and as the real
     ``repro.core.remote worker`` subprocess) returns rows bit-for-bit
     equal to local execution.

All sweep tests use deterministic directory-plugin tasks (fixed synthetic
times), so equality checks are exact, and plugin tasks are resolvable in
spawned children and worker subprocesses — which also pins the
process-pool plugin-dir bootstrap fix.
"""
from __future__ import annotations

import hashlib
import json
from pathlib import Path

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    Box,
    ResultCache,
    ShardSpec,
    SweepExecutor,
    merge_shard_reports,
    partition,
    remote_platform,
    shard_of,
)
from repro.core import registry as reg
from repro.core import runner as runner_mod
from repro.core.platform import resolve
from repro.core.report import box_row_order, load_report_rows
from repro.core.shard import assigned


# -- fixtures ----------------------------------------------------------------
def make_plugin(root: Path, name: str, factor: float = 1.0) -> Path:
    """A deterministic directory-plugin task: times depend only on params."""
    d = root / name
    d.mkdir(parents=True, exist_ok=True)
    (d / "task.json").write_text(
        json.dumps(
            {
                "name": name,
                "param_space": {"a": [1, 2, 3], "b": ["x", "y"]},
                "metrics": ["avg_latency_us", "ops_per_s"],
            }
        )
    )
    (d / "run.py").write_text(
        "def main(ctx, params):\n"
        f"    t = {factor} * 1e-4 * params['a'] * (2 if params['b'] == 'y' else 1)\n"
        "    return {'times_s': [t, 2 * t], 'ops_per_iter': 100.0}\n"
    )
    return d


def plugin_box(name: str, platforms=()) -> Box:
    d = {
        "name": f"{name}_box",
        "tasks": [{"task": name, "params": {"a": [1, 2, 3], "b": ["x", "y"]}}],
    }
    if platforms:
        d["platforms"] = list(platforms)
    return Box.from_dict(d)


def _keys(seed: int, n: int = 300) -> list[str]:
    return [hashlib.sha256(f"{seed}:{i}".encode()).hexdigest() for i in range(n)]


# -- ShardSpec ---------------------------------------------------------------
def test_shard_spec_parse_and_validate():
    s = ShardSpec.parse("1/3")
    assert (s.index, s.count) == (1, 3) and str(s) == "1/3"
    assert ShardSpec.parse("0/1") == ShardSpec(0, 1)
    for bad in ("3/3", "-1/2", "1", "a/b", "1/0"):
        with pytest.raises(ValueError):
            ShardSpec.parse(bad)


def test_shard_of_bounds_and_determinism():
    keys = _keys(7, 50)
    for n in (1, 2, 5, 9):
        for k in keys:
            i = shard_of(k, n)
            assert 0 <= i < n
            assert shard_of(k, n) == i  # pure function of (key, n)
    assert all(shard_of(k, 1) == 0 for k in keys)
    with pytest.raises(ValueError):
        shard_of("k", 0)


# -- partition laws (property tests) -----------------------------------------
@settings(max_examples=25)
@given(st.integers(min_value=1, max_value=16), st.integers(min_value=0, max_value=10**6))
def test_partition_is_disjoint_cover(n, seed):
    keys = _keys(seed, 60)
    parts = partition(keys, n)
    assert len(parts) == n
    union = [k for part in parts for k in part]
    assert sorted(union) == sorted(keys)  # cover, nothing duplicated or lost
    for i, part in enumerate(parts):
        assert all(shard_of(k, n) == i for k in part)
    # ShardSpec.assigned agrees with the partition, preserving input order.
    for i in range(n):
        assert assigned(keys, ShardSpec(i, n)) == parts[i] or sorted(
            assigned(keys, ShardSpec(i, n))
        ) == sorted(parts[i])


@settings(max_examples=25)
@given(st.integers(min_value=2, max_value=12))
def test_resize_stability(n):
    """n -> n+1 shards: >= (1 - 2/n) of keys keep their shard, and every
    key that moves, moves to the NEW shard (rendezvous-hash guarantee)."""
    keys = _keys(42)
    moved = 0
    for k in keys:
        before, after = shard_of(k, n), shard_of(k, n + 1)
        if before != after:
            moved += 1
            assert after == n  # movers only ever go to the added shard
    assert moved / len(keys) <= 2 / n


# -- shard conformance across pool kinds -------------------------------------
@pytest.mark.parametrize(
    "pool,workers", [("thread", 1), ("thread", 4), ("process", 2)]
)
def test_sharded_union_matches_unsharded(tmp_path, pool, workers):
    name = f"confplug_{pool}_{workers}"
    make_plugin(tmp_path, name)
    reg.load_plugin_dir(tmp_path / name)
    box = plugin_box(name)

    def ex():
        return SweepExecutor(pool=pool, workers=workers)

    full = ex().run_box(box)
    assert not full.errors and full.stats.total == 6
    for n in (2, 3):
        shards = [ex().run_box(box, shard=ShardSpec(i, n)) for i in range(n)]
        assert all(not s.errors for s in shards)
        assert sum(s.stats.total for s in shards) == full.stats.total  # cover
        merged = merge_shard_reports([s.rows for s in shards], box=box)
        assert merged == full.rows  # bit-for-bit, canonical order


def test_sharded_union_matches_unsharded_multi_platform(tmp_path):
    make_plugin(tmp_path, "mplug")
    reg.load_plugin_dir(tmp_path / "mplug")
    box = plugin_box("mplug", platforms=("cpu-host", "dpu-sim"))
    full = SweepExecutor(workers=3).run_box(box)
    assert full.stats.total == 12 and not full.errors
    shards = [SweepExecutor(workers=3).run_box(box, shard=ShardSpec(i, 2)) for i in range(2)]
    merged = merge_shard_reports([s.rows for s in shards], box=box)
    assert merged == full.rows
    assert [r["platform"] for r in merged[:6]] == ["cpu-host"] * 6


def test_merge_without_box_is_deterministic(tmp_path):
    make_plugin(tmp_path, "nbplug")
    reg.load_plugin_dir(tmp_path / "nbplug")
    box = plugin_box("nbplug")
    shards = [SweepExecutor().run_box(box, shard=ShardSpec(i, 2)) for i in range(2)]
    a = merge_shard_reports([shards[0].rows, shards[1].rows])
    b = merge_shard_reports([shards[1].rows, shards[0].rows])
    assert a == b  # shard arrival order cannot change the merged table
    assert sorted(map(str, a)) == sorted(
        map(str, shards[0].rows + shards[1].rows)
    )


def test_box_row_order_covers_grid(tmp_path):
    make_plugin(tmp_path, "ordplug")
    reg.load_plugin_dir(tmp_path / "ordplug")
    box = plugin_box("ordplug", platforms=("cpu-host", "dpu-sim"))
    keys = box_row_order(box)
    assert len(keys) == 12 and len(set(keys)) == 12
    assert keys[0][0] == "cpu-host" and keys[-1][0] == "dpu-sim"


def test_cache_dedupes_across_shards(tmp_path):
    make_plugin(tmp_path, "cacheplug")
    reg.load_plugin_dir(tmp_path / "cacheplug")
    box = plugin_box("cacheplug")
    path = tmp_path / "cache.json"

    # Shards populate one shared cache...
    for i in range(2):
        res = SweepExecutor(cache=ResultCache(path)).run_box(box, shard=ShardSpec(i, 2))
        assert res.stats.cached == 0 and res.stats.executed == res.stats.total
    # ...and the unsharded run re-measures nothing: shard identity == cache identity.
    full = SweepExecutor(cache=ResultCache(path)).run_box(box)
    assert full.stats.cached == full.stats.total == 6
    # Re-running one shard is fully cached too.
    again = SweepExecutor(cache=ResultCache(path)).run_box(box, shard=ShardSpec(0, 2))
    assert again.stats.cached == again.stats.total


# -- cache trust: task-source fingerprint ------------------------------------
def test_editing_task_source_misses_cache(tmp_path):
    d = make_plugin(tmp_path, "fpplug")
    reg.load_plugin_dir(d)
    box = plugin_box("fpplug")
    path = tmp_path / "cache.json"

    first = SweepExecutor(cache=ResultCache(path)).run_box(box)
    assert first.stats.cached == 0
    warm = SweepExecutor(cache=ResultCache(path)).run_box(box)
    assert warm.stats.cached == 6  # unchanged source -> warm

    make_plugin(tmp_path, "fpplug", factor=2.0)  # edit run.py in place
    stale = SweepExecutor(cache=ResultCache(path)).run_box(box)
    assert stale.stats.cached == 0  # changed source -> full remeasure
    assert stale.rows != warm.rows  # and the new code's numbers are reported
    assert SweepExecutor(cache=ResultCache(path)).run_box(box).stats.cached == 6


# -- process-pool plugin-dir bootstrap (regression) --------------------------
def test_process_pool_runs_plugin_dir_tasks(tmp_path):
    """Spawn children only see importable built-ins; the parent's plugin
    dirs must be threaded into their bootstrap payload."""
    make_plugin(tmp_path, "procplug")
    reg.load_plugin_dir(tmp_path / "procplug")
    box = plugin_box("procplug")
    res = SweepExecutor(pool="process", workers=2).run_box(box)
    assert not res.errors
    assert len(res.results) == 6
    assert res.rows == SweepExecutor().run_box(box).rows


# -- remote transport --------------------------------------------------------
@pytest.fixture()
def loopback_worker(tmp_path):
    from repro.core.remote import WorkerServer

    server = WorkerServer()
    server.serve_in_thread()
    yield server
    server.shutdown()
    server.server_close()


def test_remote_rows_match_local_bit_for_bit(tmp_path, loopback_worker):
    make_plugin(tmp_path, "rplug")
    reg.load_plugin_dir(tmp_path / "rplug")
    box = plugin_box("rplug")
    local = SweepExecutor(workers=2).run_box(box)
    rem = SweepExecutor(workers=2, remote=loopback_worker.endpoint).run_box(box)
    assert not rem.errors
    assert rem.rows == local.rows


def test_remote_platform_kind_dispatches(tmp_path, loopback_worker):
    make_plugin(tmp_path, "rkplug")
    reg.load_plugin_dir(tmp_path / "rkplug")
    box = plugin_box("rkplug")
    plat = remote_platform(loopback_worker.endpoint, base="cpu-host")
    assert plat.kind == "remote" and plat.flags["endpoint"] == loopback_worker.endpoint
    rem = SweepExecutor(platforms=[plat]).run_box(box)
    local = SweepExecutor(platforms=["cpu-host"]).run_box(box)
    assert not rem.errors and rem.rows == local.rows
    # Declaring the same platform as a box dict also resolves to remote.
    spec = {"name": "bf2", "kind": "remote", "endpoint": loopback_worker.endpoint}
    assert resolve(spec).kind == "remote"
    assert resolve(spec).flags["endpoint"] == loopback_worker.endpoint


def test_remote_worker_streams_samples_back(tmp_path, loopback_worker):
    from repro.core.executor import _unit_payload
    from repro.core.remote import get_transport, samples_from_wire

    make_plugin(tmp_path, "splug")
    reg.load_plugin_dir(tmp_path / "splug")
    ex = SweepExecutor()
    unit = ex._expand_units(plugin_box("splug"), ex.platforms)[0]
    transport = get_transport(loopback_worker.endpoint)
    resp = transport.run_unit(_unit_payload(unit, ex, want_samples=True))
    samples = samples_from_wire(resp["samples"])
    assert samples.times_s == [1e-4, 2e-4]
    assert samples.ops_per_iter == 100.0
    # Without the opt-in, samples stay off the wire (and off the process
    # pool's pickle path).
    assert "samples" not in transport.run_unit(_unit_payload(unit, ex))


def test_remote_error_reporting(tmp_path, loopback_worker):
    from repro.core.platform import Platform
    from repro.core.remote import RemoteExecutionError, get_transport

    box = Box.from_dict({"name": "b", "tasks": [{"task": "no_such_task_anywhere"}]})
    with pytest.raises(KeyError):
        # Box validation happens locally, before any dispatch.
        SweepExecutor(remote=loopback_worker.endpoint).run_box(box)

    # A kind="remote" platform without an endpoint fails every unit loudly.
    make_plugin(tmp_path, "neplug")
    reg.load_plugin_dir(tmp_path / "neplug")
    res = SweepExecutor(platforms=[Platform(name="lost", kind="remote")]).run_box(
        plugin_box("neplug")
    )
    assert res.stats.errors == 6 and not res.results
    assert all("endpoint" in e["error"] for e in res.errors)

    # An unreachable worker surfaces as RemoteExecutionError, not a hang.
    with pytest.raises(RemoteExecutionError):
        get_transport("127.0.0.1:9").run_unit({"task": "x"})


def test_sharded_remote_union_matches_local(tmp_path, loopback_worker):
    """The full distributed story: shards x remote == one local run."""
    make_plugin(tmp_path, "drplug")
    reg.load_plugin_dir(tmp_path / "drplug")
    box = plugin_box("drplug")
    local = SweepExecutor().run_box(box)
    shards = [
        SweepExecutor(remote=loopback_worker.endpoint).run_box(box, shard=ShardSpec(i, 2))
        for i in range(2)
    ]
    assert all(not s.errors for s in shards)
    assert merge_shard_reports([s.rows for s in shards], box=box) == local.rows


def test_remote_results_do_not_alias_local_cache(tmp_path, loopback_worker):
    """--remote measurements are a different measurement: a shared cache
    must keep them apart from local ones (but dedupe remote-vs-remote)."""
    make_plugin(tmp_path, "aliasplug")
    reg.load_plugin_dir(tmp_path / "aliasplug")
    box = plugin_box("aliasplug")
    path = tmp_path / "cache.json"
    local = SweepExecutor(cache=ResultCache(path)).run_box(box)
    assert local.stats.cached == 0
    rem = SweepExecutor(cache=ResultCache(path), remote=loopback_worker.endpoint).run_box(box)
    assert rem.stats.cached == 0  # remote run must NOT hit local entries
    rem2 = SweepExecutor(cache=ResultCache(path), remote=loopback_worker.endpoint).run_box(box)
    assert rem2.stats.cached == 6  # ...but does dedupe against itself
    # Shard assignment ignores the endpoint: local and remote runners
    # pointed at any workers still cover the grid identically.
    n_local = [
        SweepExecutor().run_box(box, shard=ShardSpec(i, 2)).stats.total for i in range(2)
    ]
    n_rem = [
        SweepExecutor(remote=loopback_worker.endpoint)
        .run_box(box, shard=ShardSpec(i, 2))
        .stats.total
        for i in range(2)
    ]
    assert n_local == n_rem


def test_merge_keeps_legitimate_duplicate_grid_points(tmp_path):
    """Overlapping task specs emit the same grid point twice; the merged
    table must keep both rows, exactly like the unsharded run does."""
    make_plugin(tmp_path, "dupplug")
    reg.load_plugin_dir(tmp_path / "dupplug")
    box = Box.from_dict(
        {
            "name": "dup_box",
            "tasks": [
                {"task": "dupplug", "params": {"a": [1, 2], "b": ["x"]}},
                {"task": "dupplug", "params": {"a": [2, 3], "b": ["x"]}},
            ],
        }
    )
    full = SweepExecutor().run_box(box)
    assert full.stats.total == 4  # a=2 appears twice, once per spec
    shards = [SweepExecutor().run_box(box, shard=ShardSpec(i, 2)) for i in range(2)]
    merged = merge_shard_reports([s.rows for s in shards], box=box)
    assert merged == full.rows


def test_worker_subprocess_round_trip(tmp_path):
    """End-to-end through the real `python -m repro.core.remote worker`."""
    from repro.core.remote import LocalWorker

    d = make_plugin(tmp_path, "subplug")
    reg.load_plugin_dir(d)
    box = plugin_box("subplug")
    local = SweepExecutor().run_box(box)
    with LocalWorker(plugin_dirs=[d]) as w:
        rem = SweepExecutor(remote=w.endpoint).run_box(box)
    assert not rem.errors
    assert rem.rows == local.rows


def test_parse_endpoint():
    from repro.core.remote import parse_endpoint

    assert parse_endpoint("127.0.0.1:7177") == ("127.0.0.1", 7177)
    assert parse_endpoint("tcp://bf2:9000") == ("bf2", 9000)
    assert parse_endpoint(":8080") == ("127.0.0.1", 8080)
    with pytest.raises(ValueError):
        parse_endpoint("no-port")


# -- CLI: --shard / --merge / report files -----------------------------------
def test_runner_cli_shard_merge_matches_full_run(tmp_path):
    d = make_plugin(tmp_path, "cliplug")
    bf = tmp_path / "box.json"
    bf.write_text(
        json.dumps(
            {
                "name": "cli_box",
                "tasks": [{"task": "cliplug", "params": {"a": [1, 2, 3], "b": ["x", "y"]}}],
            }
        )
    )
    common = ["--box", str(bf), "--plugin-dir", str(d), "--iters", "2", "--warmup", "0"]
    full, s0, s1, merged = (tmp_path / n for n in ("full.csv", "s0.csv", "s1.csv", "merged.csv"))

    assert runner_mod.main([*common, "--out", str(full)]) == 0
    assert runner_mod.main([*common, "--shard", "0/2", "--out", str(s0)]) == 0
    assert runner_mod.main([*common, "--shard", "1/2", "--out", str(s1)]) == 0
    assert runner_mod.main([*common, "--merge", str(s0), str(s1), "--out", str(merged)]) == 0
    assert merged.read_text() == full.read_text()  # row-identical CSV

    # JSON shard reports merge identically (typed round trip).
    j0, j1, jm = (tmp_path / n for n in ("s0.json", "s1.json", "m.csv"))
    assert runner_mod.main([*common, "--shard", "0/2", "--format", "json", "--out", str(j0)]) == 0
    assert runner_mod.main([*common, "--shard", "1/2", "--format", "json", "--out", str(j1)]) == 0
    assert runner_mod.main([*common, "--merge", str(j0), str(j1), "--out", str(jm)]) == 0
    assert jm.read_text() == full.read_text()
    assert load_report_rows(j0) + load_report_rows(j1)  # both parse, non-empty union
