"""End-to-end behaviour of the dpBento framework core: task abstraction,
box expansion, runner workflow, plugins, metrics, reporting."""
from __future__ import annotations

import json
import math
import textwrap

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Box, Runner, Samples, TaskSpec, compute_metrics
from repro.core import registry as reg
from repro.core.report import merge_platform_reports, speedup_table, to_csv
from repro.core.task import Task


class _FakeTask(Task):
    """Deterministic task recording its lifecycle (no jax involved)."""

    name = "fake"
    param_space = {"a": [1, 2], "b": ["x", "y", "z"]}
    default_metrics = ("avg_latency_us", "ops_per_s")

    def __init__(self):
        self.events: list[str] = []

    def prepare(self, ctx):
        self.events.append("prepare")
        ctx.scratch["ready"] = True

    def run(self, ctx, params):
        assert ctx.scratch.get("ready"), "run before prepare"
        self.events.append(f"run:{params['a']}{params['b']}")
        t = 1e-3 * params["a"]
        return Samples(times_s=[t, t * 2], ops_per_iter=100.0)

    def clean(self, ctx):
        self.events.append("clean")
        super().clean(ctx)


@pytest.fixture()
def fake_task():
    t = _FakeTask()
    reg._register_for_tests(t)
    return t


def test_box_cross_product(fake_task):
    box = Box.from_dict(
        {"name": "b", "tasks": [{"task": "fake", "params": {"a": [1, 2], "b": ["x", "y"]}}]}
    )
    assert box.total_tests() == 4
    expanded = box.tasks[0].expand()
    assert {(e["a"], e["b"]) for e in expanded} == {(1, "x"), (1, "y"), (2, "x"), (2, "y")}


def test_runner_workflow_prepare_once(fake_task):
    box = Box.from_dict(
        {"name": "b", "tasks": [{"task": "fake", "params": {"a": [1, 2], "b": ["x"]}}]}
    )
    r = Runner()
    res = r.run_box(box)
    assert fake_task.events.count("prepare") == 1
    assert len(res.results) == 2 and not res.errors
    # second box reuses prepared state (paper: clean is explicit/deferred)
    r.run_box(box)
    assert fake_task.events.count("prepare") == 1
    assert "clean" not in fake_task.events
    r.clean("fake")
    assert fake_task.events.count("clean") == 1


def test_runner_reports_metrics(fake_task):
    box = Box.from_dict(
        {"name": "b", "tasks": [{"task": "fake", "params": {"a": [1], "b": ["x"]},
                                 "metrics": ["p99_latency_us", "min_latency_us"]}]}
    )
    res = Runner().run_box(box)
    row = res.rows[0]
    assert row["task"] == "fake" and row["param:a"] == 1
    assert row["min_latency_us"] == pytest.approx(1e3)
    assert "p99_latency_us" in row
    csv = res.csv()
    assert "param:a" in csv.splitlines()[0]
    md = res.markdown()
    assert md.startswith("|")


def test_runner_error_isolation(fake_task):
    class _Boom(Task):
        name = "boom"
        param_space = {"z": [0, 1]}

        def run(self, ctx, params):
            if params["z"] == 1:
                raise RuntimeError("kaput")
            return Samples(times_s=[1e-3])

    reg._register_for_tests(_Boom())
    box = Box.from_dict(
        {"name": "b", "tasks": [{"task": "boom", "params": {"z": [0, 1]}},
                                {"task": "fake", "params": {"a": [1], "b": ["x"]}}]}
    )
    res = Runner().run_box(box)
    assert len(res.errors) == 1 and "kaput" in res.errors[0]["error"]
    assert any(r.task == "fake" for r in res.results)  # later tasks still ran


def test_unknown_params_rejected(fake_task):
    box = Box.from_dict({"name": "b", "tasks": [{"task": "fake", "params": {"nope": [1]}}]})
    with pytest.raises(ValueError, match="unknown params"):
        Runner().run_box(box)


def test_directory_plugin(tmp_path, fake_task):
    plug = tmp_path / "myplug"
    plug.mkdir()
    (plug / "task.json").write_text(json.dumps(
        {"name": "myplug", "param_space": {"n": [1, 2]}, "metrics": ["ops_per_s"]}
    ))
    (plug / "run.py").write_text(textwrap.dedent("""
        def main(ctx, params):
            return {"times_s": [0.001 * params["n"]], "ops_per_iter": 50.0}
    """))
    task = reg.load_plugin_dir(plug)
    assert task.name == "myplug"
    box = Box.from_dict({"name": "b", "tasks": [{"task": "myplug", "params": {"n": [1, 2]}}]})
    res = Runner().run_box(box)
    assert not res.errors and len(res.results) == 2
    assert res.results[0].metrics["ops_per_s"] == pytest.approx(50.0 / 0.001)


def test_cross_platform_report():
    rows_a = [{"task": "t", "param:x": 1, "ops_per_s": 100.0}]
    rows_b = [{"task": "t", "param:x": 1, "ops_per_s": 400.0}]
    merged = merge_platform_reports({"host": rows_a, "dpu": rows_b})
    sp = speedup_table(merged, "ops_per_s", "host")
    assert sp[0]["speedup:dpu"] == pytest.approx(4.0)
    assert "platform" in to_csv(merged).splitlines()[0]


# -- metrics properties ------------------------------------------------------
@given(st.lists(st.floats(1e-6, 10.0), min_size=1, max_size=50))
@settings(max_examples=50, deadline=None)
def test_metric_bounds(times):
    s = Samples(times_s=times, ops_per_iter=10.0, bytes_per_iter=100.0)
    m = compute_metrics(s, ("avg_latency_us", "p50_latency_us", "p99_latency_us",
                            "min_latency_us", "ops_per_s", "bandwidth_gb_s"))
    assert m["min_latency_us"] <= m["avg_latency_us"] + 1e-9
    assert m["min_latency_us"] <= m["p50_latency_us"] <= m["p99_latency_us"] + 1e-9
    assert m["ops_per_s"] == pytest.approx(10.0 / min(times))
    assert not math.isnan(m["bandwidth_gb_s"])


@given(
    st.dictionaries(
        st.sampled_from(["a", "b", "c"]),
        st.lists(st.integers(0, 3), min_size=1, max_size=3),
        min_size=1,
    )
)
@settings(max_examples=50, deadline=None)
def test_box_expansion_counts(params):
    spec = TaskSpec(task="fake", params=params)
    expanded = spec.expand()
    # expansion is the cross-product of the UNIQUE values per parameter
    expect = 1
    for v in params.values():
        expect *= len(set(v))
    assert len(expanded) == expect
    assert len({tuple(sorted(e.items())) for e in expanded}) == expect
