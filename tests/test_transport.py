"""Async multiplexed transport + cache-mediated work stealing conformance.

Four pillars, matching the PR's contract:

  1. *Multiplexing* — one persistent connection per worker carries dozens
     of id-tagged units concurrently; responses demux by request id (the
     hammer test proves it with injective per-unit metrics), deadlines and
     connection loss surface as ``WorkerUnreachable`` without killing the
     loop, and seeded slow/partial faults recover through resubmission to
     unit-for-unit equality with sequential execution.
  2. *Scheduler async sinks* — callback sinks are driven by ONE dispatcher
     thread regardless of fleet capacity (``threads_started`` is the
     benchmark's assert metric), dead sinks' threads are pruned, and
     ``close()`` joins within a total bound.
  3. *Work stealing* — exclusive claim records in the shared ResultCache
     elect one stealer per unit; a drained shard runs sibling leftovers and
     publishes them, the owner picks them up as hits, and the merged report
     stays byte-identical to the unsharded run.
  4. *Advertised capacity* — registry heartbeats carry capacity/throughput,
     so fleet discovery and ``@auto`` weights need zero startup pings.
"""
from __future__ import annotations

import argparse
import json
import socket
import threading
import time
from pathlib import Path

import pytest
from test_shard import make_plugin, plugin_box

from repro.core import config as config_mod
from repro.core import registry as reg
from repro.core import remote as remote_mod
from repro.core.aiotransport import AsyncFleetTransport
from repro.core.cache import ResultCache
from repro.core.executor import SweepExecutor, _unit_payload
from repro.core.faults import FaultSpec, inject
from repro.core.remote import LocalWorker, WorkerServer, WorkerUnreachable
from repro.core.report import to_csv
from repro.core.scheduler import FleetScheduler, Sink, WorkItem
from repro.core.shard import ShardSpec
from repro.core import merge_shard_reports
from repro.runtime.elastic import FleetWatcher
from repro.runtime.membership import MembershipRegistry, MembershipServer


# -- fixtures ----------------------------------------------------------------
def make_wide_plugin(root: Path, name: str, n_a: int = 16) -> Path:
    """A 64-unit plugin task whose metrics are INJECTIVE in params — any
    response demuxed to the wrong request id produces a visible mismatch."""
    d = root / name
    d.mkdir(parents=True, exist_ok=True)
    (d / "task.json").write_text(
        json.dumps(
            {
                "name": name,
                "param_space": {"a": list(range(1, n_a + 1)), "b": ["w", "x", "y", "z"]},
                "metrics": ["avg_latency_us", "ops_per_s"],
            }
        )
    )
    (d / "run.py").write_text(
        "def main(ctx, params):\n"
        "    mult = {'w': 1, 'x': 2, 'y': 3, 'z': 5}[params['b']]\n"
        "    t = 1e-6 * (101 * params['a'] + mult)\n"
        "    return {'times_s': [t, 2 * t], 'ops_per_iter': 100.0}\n"
    )
    return d


def _hammer_env(tmp_path, capacity: int = 64):
    """(server, aio, payloads, expected) over a 64-unit injective task."""
    from repro.core import Box

    d = make_wide_plugin(tmp_path, "ham")
    reg.load_plugin_dir(d)
    box = Box.from_dict(
        {
            "name": "ham_box",
            "tasks": [
                {
                    "task": "ham",
                    "params": {"a": list(range(1, 17)), "b": ["w", "x", "y", "z"]},
                }
            ],
        }
    )
    ex = SweepExecutor(platforms=["cpu-host"], iters=1, warmup=0)
    units = ex._expand_candidates(box, ex.platforms)
    assert len(units) == 64
    baseline = {}
    for u in units:
        result, _ = ex._run_unit(u)
        baseline[u.index] = result.metrics
    payloads = {u.index: _unit_payload(u, ex, want_samples=False) for u in units}
    srv = WorkerServer("127.0.0.1", 0, capacity=capacity, allow_faults=True,
                       plugin_dirs=[d])
    srv.serve_in_thread()
    return srv, payloads, baseline


# -- 1. multiplexing ----------------------------------------------------------
def test_async_transport_ping_and_concurrent_demux():
    srv = WorkerServer("127.0.0.1", 0)
    srv.serve_in_thread()
    aio = AsyncFleetTransport()
    try:
        assert aio.request(srv.endpoint, {"op": "ping"}, timeout=10)["ok"]
        results: dict[int, dict] = {}
        done = threading.Event()
        lock = threading.Lock()

        def cb(i):
            def f(resp, exc):
                with lock:
                    results[i] = resp if exc is None else exc
                    if len(results) == 32:
                        done.set()
            return f

        for i in range(32):
            aio.submit(srv.endpoint, {"op": "ping"}, timeout=10, callback=cb(i))
        assert done.wait(10)
        assert all(isinstance(r, dict) and r["ok"] for r in results.values())
        assert len(aio._endpoints) == 1  # every request shared one connection
    finally:
        aio.close()
        srv.shutdown()
        srv.server_close()


def test_async_transport_unreachable_endpoint_fails_bounded():
    aio = AsyncFleetTransport()
    try:
        t0 = time.monotonic()
        with pytest.raises(WorkerUnreachable):
            aio.request("127.0.0.1:9", {"op": "ping"}, timeout=30)
        assert time.monotonic() - t0 < 10.0  # connect retries, not the timeout
    finally:
        aio.close()


def test_async_deadline_expires_but_connection_survives(tmp_path):
    """A hung unit fails by deadline; the SAME connection keeps serving."""
    srv, payloads, _ = _hammer_env(tmp_path)
    aio = AsyncFleetTransport()
    try:
        inject(srv.endpoint, FaultSpec("hang", seconds=120))
        with pytest.raises(WorkerUnreachable, match="deadline"):
            aio.request(
                srv.endpoint, {"op": "run", "payload": payloads[0]}, timeout=0.5
            )
        # late reply (if any) is dropped by id; next request just works
        assert aio.request(srv.endpoint, {"op": "ping"}, timeout=10)["ok"]
        assert len(aio._endpoints) == 1
    finally:
        aio.close()
        srv.shutdown()
        srv.server_close()


def test_async_corrupt_frame_fails_pending_then_redials(tmp_path):
    srv, payloads, baseline = _hammer_env(tmp_path)
    aio = AsyncFleetTransport()
    try:
        inject(srv.endpoint, FaultSpec("partial", units=1))
        with pytest.raises(WorkerUnreachable):
            aio.request(
                srv.endpoint, {"op": "run", "payload": payloads[0]}, timeout=30
            )
        resp = aio.request(
            srv.endpoint, {"op": "run", "payload": payloads[0]}, timeout=30
        )
        assert resp["ok"] and resp["metrics"] == baseline[0]
    finally:
        aio.close()
        srv.shutdown()
        srv.server_close()


def test_hammer_64_units_in_flight_on_one_connection(tmp_path):
    """>=64 concurrent units over ONE multiplexed connection, out-of-order
    completion demuxed by request id back to injective per-unit metrics."""
    srv, payloads, baseline = _hammer_env(tmp_path)
    aio = AsyncFleetTransport()
    try:
        # Every unit stalls 0.3 s server-side, so all 64 are in flight at
        # once before the first response comes back.
        inject(srv.endpoint, FaultSpec("slow", seconds=0.3, units=64))
        lock = threading.Lock()
        results: dict[int, dict] = {}
        outstanding = [0]
        peak = [0]
        done = threading.Event()

        def cb(idx):
            def f(resp, exc):
                with lock:
                    peak[0] = max(peak[0], outstanding[0])
                    outstanding[0] -= 1
                    results[idx] = exc if exc is not None else resp
                    if len(results) == len(payloads):
                        done.set()
            return f

        for idx, payload in payloads.items():
            with lock:
                outstanding[0] += 1
            aio.submit(
                srv.endpoint, {"op": "run", "payload": payload},
                timeout=60, callback=cb(idx),
            )
        assert done.wait(60)
        assert peak[0] >= 64, f"only {peak[0]} units were ever in flight together"
        assert len(aio._endpoints) == 1
        for idx, resp in results.items():
            assert isinstance(resp, dict) and resp["ok"], f"unit {idx}: {resp}"
            assert resp["metrics"] == baseline[idx], f"unit {idx} demuxed wrong"
    finally:
        aio.close()
        srv.shutdown()
        srv.server_close()


def test_hammer_recovers_from_slow_and_partial_faults(tmp_path):
    """Seeded slow + wire-corruption faults: resubmitting every
    WorkerUnreachable converges to unit-for-unit equality with sequential."""
    srv, payloads, baseline = _hammer_env(tmp_path)
    aio = AsyncFleetTransport()
    try:
        inject(srv.endpoint, FaultSpec("partial", units=2))
        inject(srv.endpoint, FaultSpec("slow", seconds=0.05, units=10))
        lock = threading.Lock()
        results: dict[int, dict] = {}
        failures = [0]
        done = threading.Event()

        def submit(idx):
            aio.submit(
                srv.endpoint, {"op": "run", "payload": payloads[idx]},
                timeout=60, callback=cb(idx),
            )

        def cb(idx):
            def f(resp, exc):
                if exc is not None:
                    with lock:
                        failures[0] += 1
                    submit(idx)  # resubmit until it lands
                    return
                with lock:
                    results[idx] = resp
                    if len(results) == len(payloads):
                        done.set()
            return f

        for idx in payloads:
            submit(idx)
        assert done.wait(120)
        assert failures[0] >= 1  # the partial fault really tore connections
        for idx, resp in results.items():
            assert resp["ok"] and resp["metrics"] == baseline[idx]
    finally:
        aio.close()
        srv.shutdown()
        srv.server_close()


def test_async_fleet_report_byte_identical_to_sequential(tmp_path):
    d = make_plugin(tmp_path, "abi", 2)
    reg.load_plugin_dir(d)
    box = plugin_box("abi")
    baseline = SweepExecutor(platforms=["cpu-host"], iters=1, warmup=0).run_box(box)
    with LocalWorker(plugin_dirs=[d]) as w1, LocalWorker(plugin_dirs=[d]) as w2:
        ex = SweepExecutor(
            platforms=["cpu-host"], workers=2, iters=1, warmup=0,
            remote=f"{w1.endpoint},{w2.endpoint}",
        )
        assert ex.transport == "async"  # fleet default
        res = ex.run_box(box)
    assert res.stats.errors == 0
    assert res.csv() == baseline.csv()
    # one dispatcher + the shared IO loop, NOT one thread per capacity slot
    assert 1 <= res.stats.dispatch_threads <= 2


def test_max_inflight_caps_async_admission(tmp_path):
    d = make_plugin(tmp_path, "mif", 2)
    reg.load_plugin_dir(d)
    box = plugin_box("mif")
    baseline = SweepExecutor(platforms=["cpu-host"], iters=1, warmup=0).run_box(box)
    with LocalWorker(plugin_dirs=[d], capacity=4) as w:
        ex = SweepExecutor(
            platforms=["cpu-host"], workers=2, iters=1, warmup=0,
            remote=w.endpoint, max_inflight=2,
        )
        sink = ex._fleet_sink(w.endpoint)
        assert sink.capacity == 2  # override wins over advertised 4
        res = ex.run_box(box)
    assert res.stats.errors == 0
    assert res.csv() == baseline.csv()


def test_fleet_cold_start_connects_concurrently(monkeypatch):
    """64-endpoint cold start is ONE dial+ping wave through the event loop:
    single-digit wall time, every capacity learned, and ZERO serial
    per-sink fallback pings afterwards."""
    from repro.core.aiotransport import get_async_transport

    servers = [WorkerServer("127.0.0.1", 0, capacity=2) for _ in range(64)]
    for s in servers:
        s.serve_in_thread()
    eps = [s.endpoint for s in servers]
    try:
        ex = SweepExecutor(
            platforms=["cpu-host"], workers=2, iters=1, warmup=0,
            remote=",".join(eps),
        )
        assert ex.transport == "async"
        serial_pings: list[str] = []
        orig = remote_mod.get_transport

        def counting(ep):
            serial_pings.append(ep)
            return orig(ep)

        monkeypatch.setattr(remote_mod, "get_transport", counting)
        t0 = time.monotonic()
        ex._prewarm_fleet(eps)
        sinks = [ex._fleet_sink(ep) for ep in eps]
        wall = time.monotonic() - t0
        assert wall < 10.0, f"cold start took {wall:.1f}s for 64 endpoints"
        assert [s.capacity for s in sinks] == [2] * 64  # pings all landed
        assert serial_pings == []  # capacity lookups were pure dict hits
        aio = get_async_transport()
        connected = [ep for ep in eps if ep in aio._endpoints]
        assert len(connected) == 64  # every socket opened through one loop
        # idempotent: a second wave has nothing left to ask
        ex._prewarm_fleet(eps)
        assert serial_pings == []
    finally:
        for s in servers:
            s.shutdown()
            s.server_close()


# -- TCP_NODELAY (satellite) --------------------------------------------------
def test_tcp_nodelay_on_client_and_accepted_sockets():
    seen: list[int] = []

    class RecordingServer(WorkerServer):
        def finish_request(self, request, client_address):
            try:
                super().finish_request(request, client_address)
            finally:
                try:
                    seen.append(
                        request.getsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY)
                    )
                except OSError:
                    pass

    srv = RecordingServer("127.0.0.1", 0)
    srv.serve_in_thread()
    try:
        host, port = remote_mod.parse_endpoint(srv.endpoint)
        conn = remote_mod._Conn(host, port)
        try:
            assert conn.sock.getsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY) != 0
            conn.sock.sendall(b'{"op": "ping"}\n')
            assert json.loads(conn.rfile.readline())["ok"]
        finally:
            # makefile() dup'd the fd: close BOTH so the server sees EOF
            conn.rfile.close()
            conn.close()
        deadline = time.monotonic() + 5
        while not seen and time.monotonic() < deadline:
            time.sleep(0.01)
        assert seen and seen[0] != 0  # server set NODELAY on the accepted socket
    finally:
        srv.shutdown()
        srv.server_close()


# -- 2. scheduler async sinks -------------------------------------------------
def _no_run(unit):
    raise AssertionError("run() must not be called on an async sink")


def _async_echo_sink(name: str, capacity: int, delay_s: float = 0.01) -> Sink:
    """Completes each unit from a timer thread, like a transport loop would."""

    def submit(unit, done):
        threading.Timer(delay_s, lambda: done(result=f"ran-{unit}")).start()

    return Sink(name=name, capacity=capacity, run=_no_run, submit=submit)


def test_scheduler_drives_async_sinks_with_one_dispatcher_thread():
    sched = FleetScheduler(
        [_async_echo_sink("a", 8), _async_echo_sink("b", 8)]
    )
    outcomes = sched.run([WorkItem(i) for i in range(40)])
    assert [o.result for o in outcomes] == [f"ran-{i}" for i in range(40)]
    assert all(o.error is None for o in outcomes)
    # 16 capacity slots across 2 sinks, ONE dispatcher thread total
    assert sched.threads_started == 1


def test_scheduler_async_sink_error_retries_on_other_sink():
    def failing_submit(unit, done):
        threading.Timer(0.01, lambda: done(error=RuntimeError("boom"))).start()

    bad = Sink(name="bad", capacity=2, run=lambda u: None, submit=failing_submit)
    good = _async_echo_sink("good", 2)
    sched = FleetScheduler([bad, good])
    outcomes = sched.run([WorkItem(i) for i in range(6)])
    assert all(o.error is None for o in outcomes)
    assert all(o.sink == "good" for o in outcomes)


def test_scheduler_mark_dead_prunes_finished_threads():
    def run_ok(u):
        time.sleep(0.005)
        return u, False

    sinks = [Sink(name=f"s{i}", capacity=2, run=run_ok) for i in range(3)]
    sched = FleetScheduler(sinks)
    outcomes = sched.run([WorkItem(i) for i in range(12)])
    assert all(o.error is None for o in outcomes)
    assert sched.threads_started == 6  # 3 sinks x 2 pullers over the run
    # every puller exited (run -> close joined them) and mark_dead prunes
    # the corpses instead of accumulating threads for the sweep's lifetime
    sched.mark_dead("s0")
    assert len(sched._threads) == 0


def test_scheduler_close_joins_within_total_bound():
    def wedge(u):
        time.sleep(60)
        return u, False

    sched = FleetScheduler([Sink(name=f"w{i}", capacity=4, run=wedge) for i in range(4)])

    def run():
        sched.run([WorkItem(i) for i in range(16)])

    t = threading.Thread(target=run, daemon=True)
    t.start()
    time.sleep(0.2)  # let pullers claim and wedge
    t0 = time.monotonic()
    sched.close(timeout_s=1.0)
    # 16 wedged threads, ONE shared deadline — not 16 x per-thread timeouts
    assert time.monotonic() - t0 < 3.0


# -- 3. cache-mediated work stealing ------------------------------------------
def test_claim_is_exclusive_across_threads(tmp_path):
    cache = ResultCache(tmp_path / "c.json")
    wins: list[str] = []
    barrier = threading.Barrier(8)

    def racer(name):
        barrier.wait()
        if cache.try_claim("unit-1", name):
            wins.append(name)

    threads = [threading.Thread(target=racer, args=(f"r{i}",)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(wins) == 1  # O_EXCL create: exactly one winner
    assert cache.claimed("unit-1")
    assert cache.claim_owner("unit-1") == wins[0]
    assert not cache.try_claim("unit-1", "latecomer")
    # clear() erases claims too — a stale claim would silently disable
    # stealing on the next pass
    cache.clear()
    assert not cache.claimed("unit-1")
    assert cache.try_claim("unit-1", "fresh")


def test_publish_and_refresh_cross_instance(tmp_path):
    path = tmp_path / "c.json"
    a = ResultCache(path)
    b = ResultCache(path)
    a.put("k1", {"m": 1.5}, task="t", params={}, platform="p")
    assert b.get("k1") is None  # b's memory predates the put
    a.publish("k1")
    assert b.refresh("k1") == {"m": 1.5}  # disk re-read folds it in
    assert b.get("k1") == {"m": 1.5}  # and it stays in memory
    assert b.refresh("missing") is None


def test_drained_shard_steals_sibling_leftovers(tmp_path):
    d = make_plugin(tmp_path, "stl", 2)
    reg.load_plugin_dir(d)
    box = plugin_box("stl")
    baseline = SweepExecutor(platforms=["cpu-host"], iters=1, warmup=0).run_box(box)
    path = tmp_path / "shared.json"
    # Shard 0 finishes first (runs alone) and steals ALL of shard 1's units.
    ex0 = SweepExecutor(
        platforms=["cpu-host"], iters=1, warmup=0,
        cache=ResultCache(path), steal=True,
    )
    res0 = ex0.run_box(box, shard=ShardSpec(0, 2))
    assert res0.stats.errors == 0
    assert res0.stats.stolen > 0
    # Shard 1 arrives late: every one of its units was stolen + published.
    ex1 = SweepExecutor(
        platforms=["cpu-host"], iters=1, warmup=0,
        cache=ResultCache(path), steal=True,
    )
    res1 = ex1.run_box(box, shard=ShardSpec(1, 2))
    assert res1.stats.errors == 0
    assert res1.stats.executed == 0  # all hits through claims + publish
    assert res1.stats.cached == res0.stats.stolen
    merged = merge_shard_reports([res0.rows, res1.rows], box=box)
    assert to_csv(merged) == baseline.csv()


def test_steal_skips_already_claimed_units(tmp_path):
    d = make_plugin(tmp_path, "stc", 2)
    reg.load_plugin_dir(d)
    box = plugin_box("stc")
    path = tmp_path / "shared.json"
    cache = ResultCache(path)
    ex = SweepExecutor(
        platforms=["cpu-host"], iters=1, warmup=0, cache=cache, steal=True
    )
    # Pre-claim every foreign unit as if another stealer got there first.
    _, foreign = ex._expand_partition(box, ex.platforms, ShardSpec(0, 2))
    assert foreign
    for u in foreign:
        assert cache.try_claim(u.skey, "someone-else")
    res = ex.run_box(box, shard=ShardSpec(0, 2))
    assert res.stats.errors == 0
    assert res.stats.stolen == 0  # lost every claim race, stole nothing


# -- 4. advertised capacity (zero-ping discovery) -----------------------------
def test_heartbeat_throughput_lands_in_fleet_view():
    registry = MembershipRegistry(heartbeat_interval_s=0.2)
    registry.register("w:7001", capacity=2)
    registry.handle(
        {"op": "heartbeat", "endpoint": "w:7001", "capacity": 4,
         "throughput": {"ewma_s": 0.25, "units": 10}}
    )
    rows = registry.members()
    assert rows[0]["capacity"] == 4
    assert rows[0]["throughput"] == {"ewma_s": 0.25, "units": 10}


def test_registry_discovery_needs_zero_startup_pings(tmp_path):
    """Capacity comes from heartbeat-advertised records — even for an
    endpoint that answers NO pings (nothing listens on it)."""
    srv = MembershipServer(
        "127.0.0.1", 0, registry=MembershipRegistry(heartbeat_interval_s=60.0)
    )
    srv.serve_in_thread()
    try:
        dead = "127.0.0.1:9"  # discard port: a ping would hang then fail
        srv.registry.register(dead, capacity=1)
        srv.registry.heartbeat(dead, capacity=5, throughput={"ewma_s": 0.5})
        ex = SweepExecutor(
            platforms=["cpu-host"], workers=2, iters=1, warmup=0,
            fleet_registry=srv.endpoint,
        )
        t0 = time.monotonic()
        assert ex._remote_endpoints() == [dead]
        assert ex._endpoint_capacity(dead) == 5
        weights = ex._auto_weights(1)
        assert time.monotonic() - t0 < 2.0, "discovery pinged the dead worker"
        assert len(weights) == 1
    finally:
        srv.shutdown()
        srv.server_close()


def test_fleet_watcher_observe_tap_sees_member_rows():
    srv = MembershipServer(
        "127.0.0.1", 0, registry=MembershipRegistry(heartbeat_interval_s=60.0)
    )
    srv.serve_in_thread()
    try:
        srv.registry.register("w:7001", capacity=3)
        seen: list[list[dict]] = []
        sched = FleetScheduler([Sink(name="local", capacity=1, run=lambda u: (u, False))])
        watcher = FleetWatcher(
            srv.endpoint, sched,
            make_sink=lambda ep: Sink(name=ep, capacity=1, run=lambda u: (u, False)),
            observe=seen.append,
        )
        watcher.poll_once()
        assert seen and seen[0][0]["endpoint"] == "w:7001"
        assert seen[0][0]["capacity"] == 3
    finally:
        srv.shutdown()
        srv.server_close()


# -- config surface -----------------------------------------------------------
def test_transport_flags_thread_through_config():
    p = argparse.ArgumentParser()
    config_mod.add_sweep_args(p)
    ns = p.parse_args(
        ["--transport", "threaded", "--max-inflight", "7",
         "--steal", "--shard", "0/2", "--cache", "c.json"]
    )
    cfg = config_mod.SweepConfig.from_args(ns)
    assert (cfg.transport, cfg.max_inflight, cfg.steal) == ("threaded", 7, True)
    ex = config_mod.make_executor(cfg, cache=None)
    assert (ex.transport, ex.max_inflight, ex.steal) == ("threaded", 7, True)
    errors: list[str] = []
    config_mod.validate_sweep(cfg, errors.append, ping_remote=False)
    assert errors == []


def test_steal_flag_requires_shard_and_cache():
    errors: list[str] = []
    config_mod.validate_sweep(
        config_mod.SweepConfig(steal=True), errors.append, ping_remote=False
    )
    assert any("--shard" in e for e in errors)
    errors.clear()
    config_mod.validate_sweep(
        config_mod.SweepConfig(steal=True, shard="0/2", no_cache=True),
        errors.append, ping_remote=False,
    )
    assert any("--no-cache" in e for e in errors)


def test_executor_rejects_bad_transport_knobs():
    with pytest.raises(ValueError, match="transport"):
        SweepExecutor(transport="carrier-pigeon")
    with pytest.raises(ValueError, match="max_inflight"):
        SweepExecutor(max_inflight=-1)
